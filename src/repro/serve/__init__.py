from .engine import ServeEngine, EngineStats  # noqa: F401
from .sampler import SamplerConfig, sample    # noqa: F401
from . import kv_cache                        # noqa: F401
