"""Token sampling. Nucleus (top-p) inverts the sorted-probability CDF —
the thesis' search problem executed once per sequence per decode step; the
inversion runs through the k-ary CDF kernel (kernels/cdf_search.py) or its
jnp oracle (`use_kernel=False`, the default under jit on CPU)."""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from ..kernels import ops as kops


@dataclass(frozen=True)
class SamplerConfig:
    temperature: float = 1.0
    top_p: float = 1.0
    top_k: int = 0                   # 0 = off
    use_kernel: bool = False         # route CDF inversion through Pallas


def sample(logits: jnp.ndarray, rng, cfg: SamplerConfig = SamplerConfig()):
    """logits: [B, V] -> token ids [B]."""
    B, V = logits.shape
    if cfg.temperature == 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits / cfg.temperature
    if cfg.top_k:
        kth = jax.lax.top_k(logits, cfg.top_k)[0][:, -1:]
        logits = jnp.where(logits < kth, -jnp.inf, logits)
    probs = jax.nn.softmax(logits, axis=-1)
    # sort descending; restrict to the top-p nucleus; invert the CDF at u
    order = jnp.argsort(-probs, axis=-1)
    p_sorted = jnp.take_along_axis(probs, order, axis=-1)
    cdf = jnp.cumsum(p_sorted, axis=-1)
    u = jax.random.uniform(rng, (B,), minval=1e-6, maxval=1.0)
    u = u * jnp.minimum(cfg.top_p, cdf[:, -1])        # stay inside the nucleus
    if cfg.use_kernel:
        idx = kops.topp_search(cdf, u)
    else:
        idx = jnp.sum(cdf < u[:, None], axis=-1).astype(jnp.int32)
        idx = jnp.minimum(idx, V - 1)
    return jnp.take_along_axis(order, idx[:, None], axis=-1)[:, 0].astype(jnp.int32)
