"""Token sampling. Nucleus (top-p) inverts the sorted-probability CDF —
the thesis' search problem executed once per sequence per decode step; the
inversion runs through the k-ary CDF kernel (kernels/cdf_search.py) or its
jnp oracle (`use_kernel=False`, the default under jit on CPU).

``sample_queued`` routes the inversion through a decode micro-batch queue
(``kernels.cdf_search.cdf_probe_fn`` behind ``engine.queue``, DESIGN.md
§7.1): rows are submitted per tenant and the flush inverts all pending
decode steps as one fused dispatch — bit-identical tokens to ``sample``,
because the CDF construction and the u draw are the same code path and the
queued inversion is the same searchsorted.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np
import jax
import jax.numpy as jnp

from ..kernels import ops as kops


@dataclass(frozen=True)
class SamplerConfig:
    temperature: float = 1.0
    top_p: float = 1.0
    top_k: int = 0                   # 0 = off
    use_kernel: bool = False         # route CDF inversion through Pallas


def _nucleus_cdf(logits: jnp.ndarray, rng, cfg: SamplerConfig):
    """Shared front half of nucleus sampling: temperature, top-k mask,
    descending sort, CDF, and the per-row u draw restricted to the top-p
    nucleus. Returns (order [B, V], cdf [B, V], u [B]); the token is
    ``order[b, first v with cdf[b, v] >= u[b]]``. Both the inline and the
    queued sampler call this, so their tokens are bit-identical by
    construction."""
    B, V = logits.shape
    logits = logits / cfg.temperature
    if cfg.top_k:
        kth = jax.lax.top_k(logits, cfg.top_k)[0][:, -1:]
        logits = jnp.where(logits < kth, -jnp.inf, logits)
    probs = jax.nn.softmax(logits, axis=-1)
    order = jnp.argsort(-probs, axis=-1)
    p_sorted = jnp.take_along_axis(probs, order, axis=-1)
    cdf = jnp.cumsum(p_sorted, axis=-1)
    u = jax.random.uniform(rng, (B,), minval=1e-6, maxval=1.0)
    u = u * jnp.minimum(cfg.top_p, cdf[:, -1])        # stay inside the nucleus
    return order, cdf, u


def sample(logits: jnp.ndarray, rng, cfg: SamplerConfig = SamplerConfig()):
    """logits: [B, V] -> token ids [B]."""
    B, V = logits.shape
    if cfg.temperature == 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    order, cdf, u = _nucleus_cdf(logits, rng, cfg)
    if cfg.use_kernel:
        idx = kops.topp_search(cdf, u)
    else:
        idx = jnp.sum(cdf < u[:, None], axis=-1).astype(jnp.int32)
        idx = jnp.minimum(idx, V - 1)
    return jnp.take_along_axis(order, idx[:, None], axis=-1)[:, 0].astype(jnp.int32)


def sample_queued(logits: jnp.ndarray, rng, cfg: SamplerConfig, queue,
                  tenants=None):
    """``sample`` with the CDF inversion routed through a decode
    micro-batch queue (``MicroBatchQueue(cdf_probe_fn())``): each tenant's
    rows are one submit, so concurrent requests' decode steps aggregate
    into one fused inversion per flush, admission-fairly shared.

    ``tenants`` — optional per-row tenant ids ([B]); rows of one tenant
    submit together (their slice of this step's batch). Greedy decoding
    (temperature 0) has no inversion to batch and bypasses the queue.
    Tokens are bit-identical to ``sample``: same CDF, same u draw, same
    searchsorted — only the dispatch granularity differs."""
    B, V = logits.shape
    if cfg.temperature == 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    order, cdf, u = _nucleus_cdf(logits, rng, cfg)
    if tenants is None:
        fut = queue.submit((cdf, u))
        idx = fut.result()
    else:
        tenants = list(tenants)
        if len(tenants) != B:
            raise ValueError(f"tenants must have one id per row: "
                             f"{len(tenants)} != {B}")
        groups: dict = {}
        for row, t in enumerate(tenants):
            groups.setdefault(t, []).append(row)
        futs = {t: queue.submit((cdf[jnp.asarray(rows)],
                                 u[jnp.asarray(rows)]), tenant=t)
                for t, rows in groups.items()}
        idx_rows = np.empty((B,), np.int32)
        for t, rows in groups.items():
            idx_rows[np.asarray(rows)] = np.asarray(futs[t].result())
        idx = jnp.asarray(idx_rows)
    return jnp.take_along_axis(order, idx[:, None], axis=-1)[:, 0].astype(jnp.int32)
